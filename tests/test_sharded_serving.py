"""Mesh-sharded serving: tensor-parallel paged decode + chunked prefill
over a ("data", "model") device mesh, proven bit-exact vs single-device.

The headline property mirrors ``tests/test_kv_pool.py``: on a forced
multi-device host mesh (``XLA_FLAGS=--xla_force_host_platform_device_count
=8``), serving a seeded randomized trace through ``ContinuousEngine`` with
a tensor-sharded model and a kv-head-sharded ``KVBlockPool`` must emit
*bit-identical tokens and kept (layer, head, position) sets* per request
as single-device serving — for servable single-pass policies, at model
axis sizes 2 and 4, on both the jnp and forced-Pallas dispatch paths.

Why exactness is even on the table: every dot on the sharded path runs
under manual shard_map with its contraction in single-device order.
Heads are data-parallel inside attention (contiguous kv-head shards own
exactly their q heads' GQA groups, each per-head reduction sweeps the
full sequence unsplit), q/k/v and wo and the MLP run column-parallel —
full contraction per local output column, activations all-gathered
*inside* shard_map where a reduction spans a sharded dim — so no psum
ever touches a summation.  GSPMD alone cannot promise this: its dot
realization is shape-dependent and free to re-associate the bf16 sums
(observed at chunk=32 with 31-token prompts before the manual TP).

Runs only under a forced >= 8-device host (the CI multi-device job);
skips cleanly in the single-device tier-1 run.
"""

import dataclasses

import jax
import pytest

from repro.configs import get_smoke_config
from repro.core.lookahead import init_lookahead_params
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as tf
from repro.serving import KVBlockPool
from trace_utils import kept_sets, make_trace_requests, run_trace

pytestmark = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")

# two servable single-pass policies spanning both scoring families:
# attention-mass accumulation (h2o) and the trained observation pass
POLICIES = ("h2o", "lookaheadkv")
CHUNK = 128


@pytest.fixture(scope="module")
def model():
    # the smoke arch's (3 q, 1 kv) heads divide nothing: rebuild it with a
    # GQA geometry divisible by model in {2, 4} (8 q over 4 kv groups)
    base = get_smoke_config("smollm-135m")
    cfg = dataclasses.replace(
        base, name="smollm-smoke-tp", d_model=128,
        attn=dataclasses.replace(base.attn, num_heads=8, num_kv_heads=4,
                                 head_dim=16))
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    lkv = init_lookahead_params(jax.random.PRNGKey(1), cfg, params["layers"])
    return cfg, params, lkv


def _requests(cfg, seed=3):
    return make_trace_requests(cfg, chunk=CHUNK, seed=seed, n_requests=3,
                               max_new=4)


def _pool(cfg, mesh=None):
    return KVBlockPool(cfg, block_size=16, num_blocks=128, mesh=mesh)


_BASELINE: dict = {}


def _baseline(model, policy):
    """Single-device reference run, computed once per policy per dispatch
    path (the module is invoked separately under REPRO_FORCE_PALLAS)."""
    if policy not in _BASELINE:
        cfg, params, lkv = model
        done, _ = run_trace(cfg, params, lkv, policy=policy,
                            requests=_requests(cfg), chunk=CHUNK,
                            kv_pool=_pool(cfg), decode_chunk=2)
        _BASELINE[policy] = done
    return _BASELINE[policy]


@pytest.mark.parametrize("policy", POLICIES)
@pytest.mark.parametrize("model_shards", [2, 4])
def test_sharded_serving_bit_exact(model, policy, model_shards):
    cfg, params, lkv = model
    base = _baseline(model, policy)
    mesh = make_host_mesh(model=model_shards)
    got, eng = run_trace(cfg, params, lkv, policy=policy,
                         requests=_requests(cfg), chunk=CHUNK,
                         kv_pool=_pool(cfg, mesh=mesh), mesh=mesh,
                         decode_chunk=2)
    for uid, want in base.items():
        r = got[uid]
        assert r.out_tokens == want.out_tokens, \
            f"policy={policy} model={model_shards} uid={uid}: tokens diverged"
        assert kept_sets(r.admission_cache) == kept_sets(
            want.admission_cache), \
            f"policy={policy} model={model_shards} uid={uid}: kept sets " \
            "diverged"
    # satellite observability: the mesh shape reaches engine + pool stats
    assert eng.stats["mesh"] == {"data": 8 // model_shards,
                                 "model": model_shards}
    s = eng.stats["kv_pool"]
    assert s["mesh_model"] == model_shards
    assert s["bytes_total_per_shard"] == s["bytes_total"] // model_shards


def test_mesh_keys_fork_the_compile_cache(model):
    """Programs compiled against one mesh are not reusable on another: the
    chunk compile cache keys a non-trivial mesh signature, while meshless
    serving keeps the bare 4-tuple keys older tests pin."""
    cfg, params, lkv = model
    _, plain = run_trace(cfg, params, lkv, policy="h2o",
                         requests=_requests(cfg), chunk=CHUNK,
                         kv_pool=_pool(cfg), decode_chunk=2)
    for key in plain.chunk_cache.keys:
        assert len(key) == 4, key
    mesh = make_host_mesh(model=2)
    _, sharded = run_trace(cfg, params, lkv, policy="h2o",
                           requests=_requests(cfg), chunk=CHUNK,
                           kv_pool=_pool(cfg, mesh=mesh), mesh=mesh,
                           decode_chunk=2)
    for key in sharded.chunk_cache.keys:
        assert key[-1] == (("data", 4), ("model", 2)), key


def test_pool_mesh_must_match_engine_mesh(model):
    cfg, params, lkv = model
    mesh = make_host_mesh(model=2)
    with pytest.raises(AssertionError, match="different mesh"):
        run_trace(cfg, params, lkv, policy="h2o", requests=_requests(cfg),
                  chunk=CHUNK, kv_pool=_pool(cfg), mesh=mesh,
                  decode_chunk=2)


def test_pool_rejects_indivisible_mesh():
    # 1 kv head cannot shard over model=2: the pool fails loudly instead
    # of silently replicating under a sharded engine
    cfg = get_smoke_config("smollm-135m")
    with pytest.raises(AssertionError, match="divide the model axis"):
        KVBlockPool(cfg, block_size=16, num_blocks=32,
                    mesh=make_host_mesh(model=2))
