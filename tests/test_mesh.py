"""``launch.mesh`` + ``common.sharding`` mesh helpers.

Shape-level properties that hold at any forced host device count: the CI
multi-device job runs this under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` where every split
of 8 is exercised; the single-device tier-1 run still covers the
degenerate (1, 1) mesh, the non-divisible assert, and the multi-pod
axis-name paths (buildable on one device as a (1, 1, 1) mesh).
"""

import jax
import pytest

from repro.common.sharding import mesh_signature, pool_specs
from repro.configs import get_smoke_config
from repro.launch.mesh import axis_size, data_axes, make_host_mesh


def test_make_host_mesh_divisible_splits():
    n = len(jax.devices())
    for model in [m for m in (1, 2, 4, 8) if n % m == 0]:
        mesh = make_host_mesh(model=model)
        assert mesh.axis_names == ("data", "model")
        assert axis_size(mesh, "model") == model
        assert axis_size(mesh, "data") == n // model
        assert data_axes(mesh) == ("data",)


def test_make_host_mesh_non_divisible_asserts():
    n = len(jax.devices())
    with pytest.raises(AssertionError):
        make_host_mesh(model=n + 1)  # n % (n + 1) == n != 0 for n >= 1


def test_multi_pod_axis_names():
    # the production (2, 16, 16) mesh needs 512 chips, but its axis-name
    # contract is checkable on any host via a degenerate 3-axis mesh
    mesh = jax.make_mesh((1, 1, 1), ("pod", "data", "model"))
    assert data_axes(mesh) == ("pod", "data")
    assert axis_size(mesh, "pod") == 1


def test_mesh_signature_trivial_and_not():
    assert mesh_signature(None) is None
    assert mesh_signature(jax.make_mesh((1, 1), ("data", "model"))) is None
    n = len(jax.devices())
    if n > 1:
        sig = mesh_signature(make_host_mesh(model=n))
        assert sig == (("data", 1), ("model", n))


def test_pool_specs_gate_on_kv_divisibility():
    cfg = get_smoke_config("smollm-135m")  # 1 kv head
    mesh = make_host_mesh(model=1)
    assert pool_specs(cfg, None) is None
    specs = pool_specs(cfg, mesh)  # kv % 1 == 0: shardable (trivially)
    assert specs is not None and set(specs) == {"k", "v", "pos", "mask"}
    n = len(jax.devices())
    if n % 2 == 0 and n > 1:
        assert pool_specs(cfg, make_host_mesh(model=2)) is None
