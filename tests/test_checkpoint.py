"""Checkpoint round-trip regression suite (checkpoint/io.py).

Two historical corruption bugs are pinned here:

1. **Leaf ordering** — ``load(path, like)`` used to rebuild the tree from
   lexicographically sorted path keys, but ``jax.tree.flatten`` orders
   sequence children numerically, so any list of >= 10 entries (every
   per-layer list on a real arch) silently unflattened arrays into the
   wrong leaves ("10" < "2" as strings).
2. **Lossy key encoding** — path keys were mangled ``"/" -> "__"`` into npz
   member names, so a pytree key containing ``__`` corrupted its path on
   load and could collide with the ``__dtypes__``/``__meta__`` sentinels.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import io as ckpt
from repro.optim import adam


def _assert_tree_equal(a, b):
    la, ta = jax.tree.flatten(a)
    lb, tb = jax.tree.flatten(b)
    assert ta == tb
    for x, y in zip(la, lb):
        assert x.dtype == y.dtype
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_twelve_element_list_roundtrips_bit_exact(tmp_path):
    """A 12-entry list (one leaf per entry, each a distinct value) must come
    back with every array on its own leaf — the lexicographic restore put
    entry 10 where entry 2 belonged."""
    tree = {"layers": [jnp.full((3, 2), i, jnp.float32) + i / 7.0
                       for i in range(12)]}
    p = str(tmp_path / "layers.npz")
    ckpt.save(p, tree)
    back = ckpt.load(p, like=jax.tree.map(jnp.zeros_like, tree))
    _assert_tree_equal(tree, back)
    for i, leaf in enumerate(back["layers"]):
        assert float(leaf[0, 0]) == pytest.approx(i + i / 7.0)


def test_mixed_depth_sequences_roundtrip(tmp_path):
    """Nested dicts + an 11-tuple + per-entry dicts: the worst case for any
    restore order that is not the treedef order."""
    rng = np.random.default_rng(0)
    tree = {
        "blocks": tuple({"w": jnp.asarray(rng.normal(size=(2, 2)),
                                          jnp.float32),
                         "b": jnp.asarray(rng.normal(size=(2,)),
                                          jnp.float32)}
                        for _ in range(11)),
        "head": jnp.asarray(rng.normal(size=(4,)), jnp.float32),
    }
    p = str(tmp_path / "mixed.npz")
    ckpt.save(p, tree)
    back = ckpt.load(p, like=jax.tree.map(jnp.zeros_like, tree))
    _assert_tree_equal(tree, back)


def test_dunder_keys_survive(tmp_path):
    """Keys containing ``__`` (and nesting around them) must round-trip
    verbatim — the old ``"/" <-> "__"`` mangle corrupted them and collided
    with the ``__``-prefixed sentinels."""
    tree = {
        "w__a": jnp.arange(4, dtype=jnp.float32),
        "__meta__": jnp.ones((2,), jnp.float32),  # sentinel-shaped key
        "nested": {"x__y__z": jnp.full((3,), 7.0, jnp.float32)},
    }
    p = str(tmp_path / "dunder.npz")
    ckpt.save(p, tree, metadata={"tag": "t"})
    flat = ckpt.load(p)
    assert set(flat) == {"w__a", "__meta__", "nested/x__y__z"}
    back = ckpt.load(p, like=jax.tree.map(jnp.zeros_like, tree))
    _assert_tree_equal(tree, back)
    assert ckpt.metadata(p) == {"tag": "t"}


def test_bf16_and_metadata_roundtrip(tmp_path):
    tree = {"w": jnp.asarray([[1.5, -2.25]], jnp.bfloat16),
            "s": jnp.asarray(3, jnp.int32)}
    p = str(tmp_path / "bf16.npz")
    ckpt.save(p, tree, metadata={"arch": "smoke", "step": 5})
    back = ckpt.load(p, like=jax.tree.map(jnp.zeros_like, tree))
    assert back["w"].dtype == jnp.bfloat16
    _assert_tree_equal(tree, back)
    assert ckpt.metadata(p) == {"arch": "smoke", "step": 5}


def test_adam_state_roundtrips(tmp_path):
    """Trainer-state checkpoints persist ``{"lkv": tree, "opt": AdamState}``
    — the NamedTuple's field order must survive, including a >= 10-entry
    per-layer list inside mu/nu."""
    params = {"layers": [jnp.full((2,), i, jnp.float32) for i in range(10)],
              "emb": jnp.ones((3,), jnp.float32)}
    state = adam.init(params)
    state = state._replace(
        step=jnp.asarray(17, jnp.int32),
        mu=jax.tree.map(lambda x: x + 0.5, state.mu),
        nu=jax.tree.map(lambda x: x + 2.0, state.nu))
    tree = {"lkv": params, "opt": state}
    p = str(tmp_path / "train_state.npz")
    ckpt.save(p, tree)
    like = {"lkv": jax.tree.map(jnp.zeros_like, params),
            "opt": adam.init(params)}
    back = ckpt.load(p, like=like)
    assert isinstance(back["opt"], adam.AdamState)
    assert int(back["opt"].step) == 17
    _assert_tree_equal(tree, back)


def test_mismatched_tree_raises(tmp_path):
    tree = {"a": jnp.ones((2,), jnp.float32)}
    p = str(tmp_path / "m.npz")
    ckpt.save(p, tree)
    with pytest.raises(AssertionError):
        ckpt.load(p, like={"b": jnp.ones((2,), jnp.float32)})
    with pytest.raises(AssertionError):
        ckpt.load(p, like={"a": jnp.ones((3,), jnp.float32)})
